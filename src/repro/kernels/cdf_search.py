"""CDF inversion search — kernel suite v2, kernel (b).

``zen_cdf``'s faithful-paper path draws the term-2 word topic by
materializing a ``(W_shard, K)`` float CDF matrix (``cumsum`` of
``N_w|k · t4``) and binary-searching gathered rows through plain XLA.
This kernel fuses the whole chain — gather the token's *integer* count
row in the DMA engine (scalar-prefetched word ids, same trick as
``fused_gather``), multiply by the broadcast per-topic term inside the
K-tile loop, and run the lower-bound search as a running-carry count —
so neither the float CDF matrix nor the gathered ``(T, K)`` rows ever
touch HBM.

Search-as-count identity: the lower-bound index of ``target`` in
``cumsum(vals)`` equals ``sum(cdf < target)``. Counting distributes over
K tiles with two scalar carries per token: ``acc`` (mass of all previous
tiles, added to this tile's local cumsum) and ``cnt`` (matches so far).
The final ``min(cnt, k_real - 1)`` clamp covers the float edge where
``target`` exceeds the total mass (u == 1 round-off) and simultaneously
makes K-padding inert: padded columns have ``t4 == 0`` so they add no
mass, and any counts they'd contribute past ``k_real - 1`` are clamped
away. ``ref.cdf_row_search_ref`` replicates the tile-for-tile op order,
so the kernel is bit-identical to its oracle at every tile shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils.compat import pallas_tpu_compiler_params


def _cdf_search_kernel(
    # scalar prefetch
    wids_ref,  # (T,) int32 — per-token row into the count matrix
    # inputs
    row_ref,  # (1, bk) int32 — count-row tile, DMA'd via wids[token]
    term_ref,  # (1, bk) f32 — per-topic multiplier tile (t4)
    tgt_ref,  # (bt, 1) f32 — per-token inversion target
    # output
    out_ref,  # (bt, 1) int32 — lower-bound index into the row CDF
    # scratch
    acc_ref,  # (1, 1) f32 — mass of all previous K tiles
    cnt_ref,  # (1, 1) i32 — lower-bound count so far
    *,
    k_real: int,
    bt: int,
    bk: int,
):
    t = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[0, 0] = 0.0
        cnt_ref[0, 0] = 0

    vals = row_ref[...].astype(jnp.float32) * term_ref[...]
    cdf = acc_ref[0, 0] + jnp.cumsum(vals, axis=1)
    target = tgt_ref[t, 0]
    cnt_ref[0, 0] += jnp.sum((cdf < target).astype(jnp.int32))
    acc_ref[0, 0] += jnp.sum(vals)

    @pl.when(j == pl.num_programs(2) - 1)
    def _done():
        out_ref[t, 0] = jnp.minimum(cnt_ref[0, 0], k_real - 1)


def cdf_row_search_pallas(
    counts: jax.Array,  # (R, K) int32 — resident count matrix
    rows: jax.Array,  # (T,) int32 row ids into counts
    term: jax.Array,  # (K,) f32 — per-topic multiplier
    targets: jax.Array,  # (T,) f32 — inversion targets
    *,
    k_real: int,
    bt: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Lower-bound search of ``targets`` in ``cumsum(counts[rows] * term)``
    per token, fused with the row gather. T % bt == 0 and K % bk == 0
    required (``ops.cdf_row_search`` pads); ``k_real`` is the pre-padding
    topic count used for the final clamp."""
    t, k = rows.shape[0], counts.shape[1]
    assert t % bt == 0 and k % bk == 0, (t, k, bt, bk)
    grid = (t // bt, bt, k // bk)
    kernel = functools.partial(_cdf_search_kernel, k_real=k_real, bt=bt, bk=bk)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bk), lambda i, t, j, w: (w[i * bt + t], j)),
                pl.BlockSpec((1, bk), lambda i, t, j, w: (0, j)),
                pl.BlockSpec((bt, 1), lambda i, t, j, w: (i, 0)),
            ],
            out_specs=pl.BlockSpec((bt, 1), lambda i, t, j, w: (i, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.int32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((t, 1), jnp.int32),
        interpret=interpret,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
    )(
        rows.astype(jnp.int32),
        counts,
        term[None, :].astype(jnp.float32),
        targets[:, None].astype(jnp.float32),
    )
    return out[:, 0]
