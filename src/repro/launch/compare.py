"""Before/after comparison of two dry-run result stores (§Perf evidence).

    PYTHONPATH=src python -m repro.launch.compare \
        results/dryrun_baseline.json results/dryrun_opt.json
"""
from __future__ import annotations

import argparse
import json

from repro.launch.roofline import roofline_terms


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("optimized")
    ap.add_argument("--min-ratio", type=float, default=1.05,
                    help="only print cells that moved by this factor")
    args = ap.parse_args()
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.optimized) as f:
        opt = json.load(f)

    # legend: resolve each LDA arch's sampler through the backend registry
    # (the same algorithms.get() the trainer / mesh step / dryrun use).
    # Best-effort — the jax-backed imports stay inside a try so the plain
    # JSON diff below never blocks on them.
    try:
        from repro import algorithms
        from repro.configs import get_config
        from repro.configs.base import LDAArchConfig
        from repro.launch.mesh import mesh_backends
    except Exception as e:  # pragma: no cover - jax-less environments
        print(f"# (algorithm legend unavailable: {e})")
    else:
        print(f"# mesh-capable backends: {', '.join(mesh_backends())}")
        for arch in sorted({k.split("|")[0] for k in base if "|" in k}):
            try:
                cfg = get_config(arch)
                if isinstance(cfg, LDAArchConfig):
                    backend = algorithms.get(cfg.algorithm)
                    print(f"# {arch}: sampler backend {backend.name!r} "
                          f"(shard_map={backend.supports_shard_map})")
            except Exception as e:  # best-effort; never block the diff
                print(f"# {arch}: (algorithm legend unavailable: {e})")

    def effective(store, key):
        """fitted record if present, else the raw cell record."""
        arch, shape, mesh = key.split("|")
        rec = store.get(key)
        fit = store.get(f"{arch}|{shape}|fit")
        if rec is None or not rec.get("ok"):
            return None
        if mesh == "single" and fit is not None and fit.get("ok"):
            rec = dict(rec)
            for k in ("flops_per_device", "bytes_per_device",
                      "collective_bytes_per_device"):
                rec[k] = fit[k]
        return rec

    print("| cell | term | baseline (s) | optimized (s) | x |")
    print("|---|---|---|---|---|")
    keys = sorted(k for k in base if k.count("|") == 2
                  and not k.endswith("|fit"))
    for key in keys:
        b = effective(base, key)
        o = effective(opt, key)
        if b is None or o is None:
            continue
        tb = roofline_terms(b)
        to = roofline_terms(o)
        for term in ("compute_s", "memory_s", "collective_s"):
            if to[term] <= 0:
                continue
            ratio = tb[term] / max(to[term], 1e-12)
            if ratio >= args.min_ratio or ratio <= 1 / args.min_ratio:
                print(f"| {key} | {term[:-2]} | {tb[term]:.3e} | "
                      f"{to[term]:.3e} | {ratio:5.2f} |")


if __name__ == "__main__":
    main()
