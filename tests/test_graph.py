"""Graph partitioning (paper §4.1, Alg. 3 DBH+)."""
import numpy as np
import pytest

from repro.core.graph import (
    PARTITIONERS,
    dbh,
    dbh_plus,
    grid_partition,
    partition_metrics,
)
from repro.data import synthetic_corpus


@pytest.fixture(scope="module")
def skewed():
    c = synthetic_corpus(0, num_docs=400, num_words=600, avg_doc_len=40,
                         zipf_a=1.4)
    return c, np.asarray(c.word), np.asarray(c.doc)


def test_all_partitioners_valid(skewed):
    _, w, d = skewed
    for name, fn in PARTITIONERS.items():
        part = fn(w, d, 8)
        assert part.min() >= 0 and part.max() < 8, name
        m = partition_metrics(w, d, part, 8)
        assert m["edge_balance"] >= 1.0
        assert m["total_replication"] >= 1.0


def test_1d_partition_perfect_word_locality(skewed):
    _, w, d = skewed
    part = PARTITIONERS["edge_partition_1d"](w, d, 8)
    m = partition_metrics(w, d, part, 8)
    assert m["word_replication"] == 1.0


def test_dbh_beats_random_on_replication(skewed):
    _, w, d = skewed
    m_rand = partition_metrics(w, d, PARTITIONERS["random_vertex_cut"](w, d, 16), 16)
    m_dbh = partition_metrics(w, d, dbh(w, d, 16), 16)
    assert m_dbh["total_replication"] < m_rand["total_replication"]


def test_dbh_plus_improves_cold_edges():
    """Alg. 3: on a corpus with many cold edges, DBH+ lowers replication
    without hurting balance."""
    c = synthetic_corpus(1, num_docs=3000, num_words=2000, avg_doc_len=5,
                         zipf_a=1.5)
    w, d = np.asarray(c.word), np.asarray(c.doc)
    m_dbh = partition_metrics(w, d, dbh(w, d, 16), 16)
    m_plus = partition_metrics(w, d, dbh_plus(w, d, 16, threshold=8), 16)
    assert m_plus["total_replication"] <= m_dbh["total_replication"]
    assert m_plus["edge_balance"] <= m_dbh["edge_balance"] * 1.05


def test_grid_partition_roundtrip(skewed):
    corpus, w, d = skewed
    for balance in ("lpt", "hash"):
        grid = grid_partition(corpus, 2, 4, balance=balance)
        # every real token appears exactly once
        assert int(grid.mask.sum()) == corpus.num_tokens
        # relabeled ids stay within their shard's range
        rows = np.arange(8) // 4
        cols = np.arange(8) % 4
        for c_ in range(8):
            sel = grid.mask[c_]
            ws = grid.word[c_][sel]
            ds = grid.doc[c_][sel]
            assert (ws // grid.words_per_shard == cols[c_]).all()
            assert (ds // grid.docs_per_shard == rows[c_]).all()
        # permutations are injective
        assert np.unique(grid.word_perm).size == corpus.num_words
        assert np.unique(grid.doc_perm).size == corpus.num_docs


def test_lpt_balances_better_than_hash(skewed):
    corpus, _, _ = skewed
    g_lpt = grid_partition(corpus, 4, 4, balance="lpt")
    g_hash = grid_partition(corpus, 4, 4, balance="hash")
    assert g_lpt.padding_overhead <= g_hash.padding_overhead


def test_word_sorted_within_cell(skewed):
    """Word-by-word process order (paper §3.1) is the physical layout."""
    corpus, _, _ = skewed
    grid = grid_partition(corpus, 2, 2, sort_tokens_by="word")
    for c_ in range(4):
        ws = grid.word[c_][grid.mask[c_]]
        assert (np.diff(ws) >= 0).all()
