"""Sharded model-parallel serving: the frozen model over a device mesh.

Training already shards ``N_w|k`` by word rows (``core.graph`` grid
partition); this module gives the *serving* half the same layout
(DESIGN.md §5.4). A :class:`ShardedFrozenLDAModel` lays the frozen count
rows over the mesh's ``model`` axis — LPT-balanced by row token mass,
relabeled contiguous per shard exactly like ``grid_partition`` relabels
word columns — and :func:`make_sharded_sweep_fn` turns any registered
backend's ``infer_sweep`` into a ``shard_map`` dispatch over that layout.

Correctness rests on one property of the ``infer_sweep`` contract
(``algorithms/base.py``): every per-slot key is consumed at the full
(B, L) layout and every draw is per-token, so a shard that computes the
whole batch but keeps only the tokens whose word rows it owns draws
**bit-identically** to the single-host sweep. Each shard therefore:

1. maps global (relabeled) word ids to shard-local rows and masks down to
   its owned tokens;
2. runs the backend's unmodified ``infer_sweep`` on its ``(W/m, K)`` row
   block with ``num_words_total`` carrying the true W (the ``W * beta``
   denominator must not see the block shape);
3. ``psum``\\ s the owned assignments over the ``model`` axis — every real
   token is owned by exactly one shard, so the sum *is* the combined
   sweep.

Backend tables built by ``prepare_infer`` follow the same split: leaves
the backend declares in ``infer_aux_word_fields`` (word-indexed, dim 0 =
word rows — e.g. ``zen_cdf``'s per-word CDFs) are built per-shard from the
local row block; everything else (topic-indexed vectors) replicates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.types import LDAHyperParams
from repro.utils import compat


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedFrozenLDAModel:
    """A :class:`~repro.serving.lda_engine.FrozenLDAModel` laid out over a
    mesh: word rows LPT-balanced over the ``model`` axis, padded to equal
    per-shard blocks, topic totals replicated.

    Duck-types the frozen model everywhere the engine reads it
    (``num_words``/``num_topics``/``hyper``/``phi()``), but its ``n_wk``
    holds the *relabeled padded* ``(words_per_shard * m, K)`` layout — the
    engine relabels request token ids through :meth:`relabel` at slot
    placement, so only the sharded decode path ever sees shard-space ids.

    ``eq=False``: slots compare by identity (the engine pins slots with
    ``is``), never by array contents.
    """

    n_wk: jax.Array  # (W_pad, K) int32, sharded P("model", None)
    n_k: jax.Array  # (K,) int32, replicated
    hyper: LDAHyperParams
    mesh: Mesh
    word_perm: np.ndarray  # (W,) original row id -> padded shard-space row
    words_per_shard: int
    num_words_unsharded: int  # the true W

    @property
    def num_words(self) -> int:
        """The *original* vocabulary size W — token-id validation and
        ``phi()`` speak original ids, never the padded shard space."""
        return self.num_words_unsharded

    @property
    def num_topics(self) -> int:
        return int(self.n_wk.shape[1])

    @property
    def num_shards(self) -> int:
        return int(self.mesh.shape["model"])

    def relabel(self, words: np.ndarray) -> np.ndarray:
        """Original token ids -> shard-space rows (host-side, at slot
        placement). Ids must already be filtered to ``[0, W)``."""
        return self.word_perm[np.asarray(words, np.int64)].astype(np.int32)

    def phi(self) -> jax.Array:
        """Smoothed topic-word distributions in *original* word order,
        (W, K) — gathers the shards, inverts the relabeling."""
        padded = np.asarray(self.n_wk, np.float32)
        n_wk = padded[self.word_perm]  # (W, K) original order
        w_beta = self.num_words * self.hyper.beta
        return jnp.asarray(
            (n_wk + self.hyper.beta)
            / (np.asarray(self.n_k, np.float32) + w_beta)[None, :]
        )

    @classmethod
    def shard(cls, model, mesh: Mesh) -> "ShardedFrozenLDAModel":
        """Lay a frozen model out over ``mesh``'s ``model`` axis.

        Rows are LPT-assigned by token mass (hot words spread first — the
        ``grid_partition`` balance heuristic applied to serving), then
        relabeled contiguous per shard and zero-padded to the max bin
        size so every device holds one equal ``(words_per_shard, K)``
        block.
        """
        from repro.sharding.partition import shard_rows_balanced

        n_wk = np.asarray(model.n_wk)
        w, k = n_wk.shape
        m = int(mesh.shape["model"])
        perm, per = shard_rows_balanced(n_wk.sum(axis=1), m)
        padded = np.zeros((per * m, k), n_wk.dtype)
        padded[perm] = n_wk
        return cls(
            n_wk=jax.device_put(
                jnp.asarray(padded, jnp.int32),
                NamedSharding(mesh, P("model", None)),
            ),
            n_k=jax.device_put(
                jnp.asarray(model.n_k, jnp.int32), NamedSharding(mesh, P())
            ),
            hyper=model.hyper,
            mesh=mesh,
            word_perm=perm,
            words_per_shard=per,
            num_words_unsharded=w,
        )


def layout_key(model) -> Optional[Tuple[int, int, int]]:
    """The static layout a sharded jitted program closes over — two model
    slots may share jit caches only when these match (plain frozen models
    close over hyper alone and return None)."""
    if isinstance(model, ShardedFrozenLDAModel):
        return (model.words_per_shard, model.num_words_unsharded,
                model.num_shards)
    return None


def _aux_specs(backend, aux) -> Any:
    """PartitionSpec tree for a backend's ``prepare_infer`` aux: leaves
    named in ``infer_aux_word_fields`` shard their dim 0 over ``model``,
    everything else replicates."""
    word_fields = frozenset(getattr(backend, "infer_aux_word_fields", ()))
    fields = getattr(type(aux), "_fields", None)
    if fields is None:  # not a NamedTuple: nothing is word-indexed
        return jax.tree_util.tree_map(lambda _: P(), aux)
    return type(aux)(*(
        P("model", *([None] * (jnp.ndim(leaf) - 1)))
        if name in word_fields else P()
        for name, leaf in zip(fields, aux)
    ))


def sharded_prepare_infer(backend, smodel: ShardedFrozenLDAModel, knobs):
    """Build the backend's frozen serving tables per word shard.

    Each shard runs the unmodified ``prepare_infer`` on its own
    ``(words_per_shard, K)`` row block with ``num_words_total`` = the true
    W, so word-indexed tables (``infer_aux_word_fields``) come out sharded
    row-for-row with the counts and topic-indexed ones replicated —
    bit-identical rows to a single-host build, since every table row is a
    function of its own count row plus replicated vectors.
    """
    mesh, hyper = smodel.mesh, smodel.hyper
    w_total = smodel.num_words

    def build(n_wk_blk, n_k):
        return backend.prepare_infer(
            n_wk_blk, n_k, hyper, knobs, num_words_total=w_total
        )

    probe = jax.eval_shape(
        build,
        jax.ShapeDtypeStruct(
            (smodel.words_per_shard, smodel.num_topics), smodel.n_wk.dtype
        ),
        jax.ShapeDtypeStruct(smodel.n_k.shape, smodel.n_k.dtype),
    )
    if probe is None:
        return None
    specs = _aux_specs(backend, probe)
    fn = jax.jit(compat.shard_map(
        build, mesh, in_specs=(P("model", None), P()), out_specs=specs,
    ))
    return fn(smodel.n_wk, smodel.n_k)


def make_sharded_sweep_fn(backend, knobs, smodel: ShardedFrozenLDAModel,
                          aux):
    """The sharded analogue of the engine's jitted per-bucket sweep.

    Same call signature as the single-host program —
    ``fn(keys, words, mask, z, n_kd, n_wk, n_k, aux)`` with ``words``
    already in shard space (``ShardedFrozenLDAModel.relabel``) — so the
    engine's stepping loop is layout-blind. Inside the ``shard_map``
    every device computes the full (B, L) batch against its own row
    block, keeps the tokens it owns, and ``psum``\\ s assignments; keys
    cross the shard boundary as raw uint32 bits (extended key dtypes and
    ``shard_map`` disagree across jax versions)."""
    mesh, hyper = smodel.mesh, smodel.hyper
    wps, w_total = smodel.words_per_shard, smodel.num_words
    k = smodel.num_topics
    aux_spec = P() if aux is None else _aux_specs(backend, aux)

    def local(key_bits, words, mask, z, n_kd, n_wk_blk, n_k, aux_l):
        keys = jax.random.wrap_key_data(key_bits)
        col = jax.lax.axis_index("model")
        wl = words - (col * wps).astype(words.dtype)
        owned = mask & (wl >= 0) & (wl < wps)
        wl = jnp.clip(wl, 0, wps - 1)
        z_prop = backend.infer_sweep(
            keys, wl, owned, z, n_kd, n_wk_blk, n_k, hyper, knobs,
            aux_l, num_words_total=w_total,
        )
        # every live token is owned by exactly one shard: sum = combine
        return jax.lax.psum(
            jnp.where(owned, z_prop.astype(jnp.int32), 0), "model"
        )

    sharded = compat.shard_map(
        local, mesh,
        in_specs=(P(), P(), P(), P(), P(), P("model", None), P(), aux_spec),
        out_specs=P(),
    )

    def fn(keys, words, mask, z, n_kd, n_wk, n_k, aux_a):
        z_sum = sharded(
            jax.random.key_data(keys), words, mask, z, n_kd, n_wk, n_k,
            aux_a,
        )
        z_new = jnp.where(mask, z_sum, z)
        onehot = (
            jax.nn.one_hot(z_new, k, dtype=jnp.int32) * mask[..., None]
        )
        return z_new, jnp.sum(onehot, axis=1)

    return jax.jit(fn)
