"""Serving engine: batched greedy decode matches the manual decode loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import decode_step, init_cache, init_params
from repro.serving import ServeConfig, ServingEngine


def _setup(key):
    cfg = dataclasses.replace(get_config("qwen3-8b-smoke"), dtype="float32",
                              num_layers=2)
    params = init_params(key, cfg)
    return cfg, params


def test_engine_matches_manual_greedy(key):
    cfg, params = _setup(key)
    engine = ServingEngine(params, cfg, ServeConfig(max_batch=2, max_len=32))
    prompt = [5, 9, 11]
    engine.submit(prompt, max_new=4)
    done = engine.run_until_done()
    assert len(done) == 1 and len(done[0].out) == 4

    # manual single-sequence greedy decode
    cache = init_cache(cfg, 1, 32)
    tok = None
    for t in prompt:
        logits, cache = decode_step(params, cfg, jnp.asarray([t], jnp.int32),
                                    cache)
    outs = []
    for _ in range(4):
        nxt = int(jnp.argmax(logits[0]))
        outs.append(nxt)
        logits, cache = decode_step(params, cfg,
                                    jnp.asarray([nxt], jnp.int32), cache)
    assert outs == done[0].out


def test_engine_batches_multiple_requests(key):
    cfg, params = _setup(key)
    engine = ServingEngine(params, cfg, ServeConfig(max_batch=4, max_len=32))
    uids = [engine.submit([3, 1 + i], max_new=3) for i in range(4)]
    done = engine.run_until_done()
    assert sorted(r.uid for r in done) == sorted(uids)
    assert all(len(r.out) == 3 for r in done)
    # different prompts should (generically) produce different outputs
    assert len({tuple(r.out) for r in done}) > 1
