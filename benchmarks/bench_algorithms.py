"""Paper Figs. 3 + 4: every registered CGS backend — time/iteration and
log-likelihood after equal iterations, all on the shared substrate
("the only difference is the algorithm").

The sweep list IS the registry: a newly registered backend shows up here
with zero benchmark changes — on BOTH axes: the single-box sweep below,
and a mesh x backend sweep that times the distributed step for every
``supports_shard_map`` backend on a simulated 2-device CPU mesh. Both
axes drive the same ``TrainSession`` API (mesh_shape selects the plan),
so what is timed is exactly what ``launch/train.py`` runs. The mesh
cells run in a subprocess because the host device count locks at first
jax init (same trick as tests/helpers.py)."""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax

from benchmarks.common import row
from repro import algorithms
from repro.core import LDAHyperParams
from repro.data import synthetic_lda_corpus
from repro.train.session import RunConfig, TrainSession

_MESH_CHILD = """
import warnings; warnings.filterwarnings('ignore')
import time
import jax
from repro.data import synthetic_lda_corpus
from repro.core.types import LDAHyperParams
from repro.train.session import RunConfig, TrainSession
corpus, _ = synthetic_lda_corpus(0, num_docs=400, num_words=800,
                                 num_topics=32, avg_doc_len=64)
hyper = LDAHyperParams(num_topics=32, alpha=0.05, beta=0.01)
session = TrainSession(corpus, hyper,
                       RunConfig(algorithm={alg!r}, mesh_shape=(1, 2)))
state = session.init(jax.random.key(0))
state = session.step(state)  # warm compile
jax.block_until_ready(state.n_k)
t0 = time.perf_counter()
for _ in range({iters}):
    state = session.step(state)
jax.block_until_ready(state.n_k)
print('US_PER_ITER', (time.perf_counter() - t0) / {iters} * 1e6)
"""


def mesh_sweep(iters: int = 5) -> None:
    """fig3 mesh axis: distributed step time for every mesh-capable
    backend, 2 simulated CPU devices, (1, 2) data x model mesh."""
    import repro

    # repro is a namespace package (no __init__.py): locate src via __path__
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=2 "
        + env.get("XLA_FLAGS", "")
    ).strip()
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    from repro.launch.mesh import mesh_backends

    for alg in mesh_backends():
        # a bad cell (timeout, crash, missing marker) records an error row
        # and the sweep moves on — one backend never aborts the whole run
        try:
            out = subprocess.run(
                [sys.executable, "-c",
                 _MESH_CHILD.format(alg=alg, iters=iters)],
                env=env, capture_output=True, text=True, timeout=1800,
            )
        except subprocess.TimeoutExpired:
            row(f"fig3_mesh2dev_time_per_iter_{alg}", float("nan"),
                "error=timeout")
            continue
        us = next(
            (float(line.split()[1]) for line in out.stdout.splitlines()
             if line.startswith("US_PER_ITER")),
            None,
        )
        if out.returncode != 0 or us is None:
            err = out.stderr.strip().splitlines()
            row(f"fig3_mesh2dev_time_per_iter_{alg}", float("nan"),
                "error=" + err[-1][:80] if err else "error")
            continue
        row(f"fig3_mesh2dev_time_per_iter_{alg}", us)


def main(iters: int = 10):
    corpus, _ = synthetic_lda_corpus(
        0, num_docs=400, num_words=800, num_topics=32, avg_doc_len=64
    )
    hyper = LDAHyperParams(num_topics=32, alpha=0.05, beta=0.01)
    results = {}
    for alg in algorithms.registered():
        session = TrainSession(
            corpus, hyper,
            RunConfig(algorithm=alg, max_kw=64, max_kd=64, num_mh=8),
        )
        st = session.init(jax.random.key(0))
        st = session.step(st)  # warm compile
        t0 = time.perf_counter()
        for _ in range(iters):
            st = session.step(st)
        dt = (time.perf_counter() - t0) / iters
        llh = session.llh(st)
        results[alg] = (dt, llh)
        row(f"fig3_time_per_iter_{alg}", dt * 1e6, f"llh={llh:.1f}")
    # headline ratios (paper: 2-6x over LightLDA, ~14x over SparseLDA for
    # the customized-scale corpora; CPU-vectorized small-corpus ratios are
    # reported as measured)
    z = results["zen_sparse"][0]
    row("fig3_speedup_vs_lightlda", 0.0,
        f"ratio={results['lightlda'][0] / z:.2f}")
    row("fig3_speedup_vs_sparselda", 0.0,
        f"ratio={results['sparselda'][0] / z:.2f}")
    row("fig4_llh_zen_minus_lightlda", 0.0,
        f"delta={results['zen_sparse'][1] - results['lightlda'][1]:.1f}")
    mesh_sweep()


if __name__ == "__main__":
    main()
