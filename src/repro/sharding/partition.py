"""Logical-axis sharding rules: params + batches -> NamedShardings.

The mesh is ``(pod?, data, model)``. Policy (DESIGN.md §3):

* tensor parallelism over `model`: vocab rows, attention head-flat columns,
  MLP hidden, MoE experts (when divisible), mamba inner channels;
* FSDP over the data axes (`pod`+`data`): the *other* big dim of every
  matrix — this is what makes grok/arctic optimizer state fit;
* batch over the data axes; decode caches shard batch over data and the KV
  sequence over `model` (flash-decode layout; for batch=1 long-context the
  sequence is the only shardable axis).

Rules are matched on the trailing dims of each leaf by its dict-path name,
so layer-stacked leaves (leading `layers` axis) get `None` prepended
automatically.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def data_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n != "model")


def _divides(n: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    if isinstance(axes, str):
        axes = (axes,)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return n % size == 0


# trailing-dim rules per leaf name: tokens are resolved against the mesh,
# 'tp' -> model axis, 'fsdp' -> data axes, None -> replicated.
_RULES = {
    # embeddings: vocab x d_model. NO fsdp on d_model: a contraction whose
    # reduced dim is sharded over the batch axes makes the SPMD solver
    # replicate the batch through the whole (B,S,V) logits segment
    # (measured: 40 GB f32 buffers, EXPERIMENTS.md §Perf q1). Vocab TP
    # alone keeps the table ~100 MB/device — FSDP buys nothing here.
    "embed": ("tp", None),
    "lm_head": ("tp", None),
    # attention (flat layouts): d_model x (heads*hd)
    "wq": ("fsdp", "tp"),
    "wk": ("fsdp", "tp"),
    "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "bq": ("tp",),
    "bk": ("tp",),
    "bv": ("tp",),
    # MLA
    "wq_a": ("fsdp", "tp"),
    "wq_b": ("fsdp", "tp"),
    "wkv_a": ("fsdp", "tp"),
    "wkv_b": ("fsdp", "tp"),
    # MLP
    "w_gate": ("fsdp", "tp"),
    "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    # MoE (3D: experts x in x out) — expert dim preferred on `model`
    "router": ("fsdp", None),
    # SSM
    "in_proj": ("fsdp", "tp"),
    "out_proj": ("tp", "fsdp"),
    "x_proj": ("tp", None),
    "dt_proj": (None, "tp"),
    "conv_w": (None, "tp"),
    "conv_b": ("tp",),
    "a_log": ("tp", None),
    "dt_bias": ("tp",),
    "d": ("tp",),
    # mamba2 per-head vectors (H,)
    "a_log_h": ("tp",),
    "dt_bias_h": ("tp",),
    "d_h": ("tp",),
}

_MOE_LEAVES = {"w_gate", "w_up", "w_down"}


def _spec_for(
    path_names: Tuple[str, ...],
    shape: Tuple[int, ...],
    cfg: ArchConfig,
    mesh: Mesh,
    fsdp: bool,
) -> P:
    data_axes = data_axes_of(mesh)
    name = path_names[-1] if path_names else ""
    is_moe = cfg.moe is not None and "moe" in path_names and name in _MOE_LEAVES

    def resolve(token, dim):
        if token == "tp":
            return "model" if _divides(dim, mesh, "model") else None
        if token == "fsdp":
            if not fsdp:
                return None
            return data_axes if _divides(dim, mesh, data_axes) else None
        return None

    if is_moe:
        e = cfg.moe.num_experts
        ep = _divides(e, mesh, "model")
        if name in ("w_gate", "w_up"):  # (E, D, F)
            rule = (("tp" if ep else None), "fsdp", (None if ep else "tp"))
        else:  # w_down (E, F, D)
            rule = (("tp" if ep else None), (None if ep else "tp"), "fsdp")
        trailing = 3
    else:
        rule = _RULES.get(name)
        if rule is None:
            return P()  # replicate small leaves (norm scales, lengths, ...)
        trailing = len(rule)
    if len(shape) < trailing:
        return P()
    dims = shape[-trailing:]
    resolved = tuple(resolve(tok, d) for tok, d in zip(rule, dims))
    # avoid double-assigning the same mesh axis to two dims of one leaf
    seen = set()
    final = []
    for r in resolved:
        key = tuple(r) if isinstance(r, tuple) else (r,)
        if r is not None and any(k in seen for k in key):
            final.append(None)
        else:
            final.append(r)
            seen.update(k for k in key if k is not None)
    lead = (None,) * (len(shape) - trailing)
    return P(*(lead + tuple(final)))


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "name"):
            names.append(str(p.name))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
    return tuple(names)


def param_specs(params_shapes: Any, cfg: ArchConfig, mesh: Mesh,
                fsdp: bool = True) -> Any:
    """PartitionSpec pytree matching ``params_shapes`` (shapes or arrays)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(_path_names(path), leaf.shape, cfg, mesh,
                                     fsdp),
        params_shapes,
    )


def param_shardings(params_shapes: Any, cfg: ArchConfig, mesh: Mesh,
                    fsdp: bool = True) -> Any:
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        param_specs(params_shapes, cfg, mesh, fsdp),
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# row sharding for serving tables (LDA word-topic counts)
# ---------------------------------------------------------------------------

def shard_rows_balanced(
    loads: np.ndarray, shards: int
) -> Tuple[np.ndarray, int]:
    """Load-balanced contiguous row layout for sharding a table over the
    ``model`` axis: assign rows to ``shards`` bins by greedy LPT on
    ``loads`` (the same heuristic ``core.graph.grid_partition`` uses for
    word columns), then relabel so each bin's rows are contiguous and
    every bin is padded to the max bin size.

    Returns ``(perm, rows_per_shard)`` where ``perm[old_row]`` is the new
    row index in the padded ``(shards * rows_per_shard, ...)`` layout.
    Rows land in bin ``perm[r] // rows_per_shard``; pad rows (indices not
    in ``perm``'s image) are left for the caller to zero-fill.
    """
    from repro.core.graph import _balanced_ranges

    loads = np.asarray(loads, dtype=np.float64).reshape(-1)
    assign = _balanced_ranges(loads, shards)
    counts = np.bincount(assign, minlength=shards)
    per = max(int(counts.max()), 1)
    perm = np.empty(loads.shape[0], dtype=np.int64)
    for b in range(shards):
        ids = np.where(assign == b)[0]
        perm[ids] = b * per + np.arange(ids.size)
    return perm, per


# ---------------------------------------------------------------------------
# batch + cache shardings
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh, ndim: int, batch_divisible: bool = True) -> P:
    data_axes = data_axes_of(mesh)
    lead = data_axes if batch_divisible else None
    return P(*((lead,) + (None,) * (ndim - 1)))


def batch_sharding(batch_shapes: Any, mesh: Mesh) -> Any:
    """Shard dim 0 (global batch) over the data axes when divisible."""
    data_axes = data_axes_of(mesh)
    dp = int(np.prod([mesh.shape[a] for a in data_axes]))

    def one(leaf):
        ok = leaf.shape and leaf.shape[0] % dp == 0
        return NamedSharding(mesh, batch_spec(mesh, len(leaf.shape), ok))

    return jax.tree_util.tree_map(one, batch_shapes)


def cache_sharding(cache_shapes: Any, mesh: Mesh) -> Any:
    """Decode caches: (L, B, S, H?, D?) -> batch over data axes if it
    divides, else KV sequence over data axes; sequence over `model` when the
    head dim can't use it (flash-decode layout)."""
    data_axes = data_axes_of(mesh)
    dp = int(np.prod([mesh.shape[a] for a in data_axes]))
    mp = mesh.shape["model"]

    def one(leaf):
        shape = leaf.shape
        if len(shape) < 3:
            return NamedSharding(mesh, P())
        b, s = shape[1], shape[2]
        b_ax = data_axes if b % dp == 0 else None
        s_ax = "model" if s % mp == 0 and s > 1 else None
        if b_ax is None and s % (dp * mp) == 0 and s > 1:
            # batch=1 long-context: the sequence takes every axis
            spec = [None, None, data_axes + ("model",)]
        else:
            spec = [None, b_ax, s_ax]
        spec += [None] * (len(shape) - 3)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(one, cache_shapes)
