"""Small shared utilities."""
from repro.utils.prng import fold_in_str, split_like
from repro.utils.treeutil import tree_bytes, tree_param_count

__all__ = ["fold_in_str", "split_like", "tree_bytes", "tree_param_count"]
