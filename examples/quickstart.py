"""Quickstart: train ZenLDA on a synthetic corpus and print topics.

Uses the unified ``TrainSession`` API (DESIGN.md §6): one declarative
``RunConfig`` describes the whole run — algorithm, iteration count, eval
cadence — and ``session.run`` drives it (the same config with
``mesh_shape=(rows, cols)`` would run on a device mesh instead).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import LDAHyperParams
from repro.data import synthetic_lda_corpus
from repro.train.session import RunConfig, TrainSession


def main():
    corpus, true_phi = synthetic_lda_corpus(
        seed=0, num_docs=200, num_words=300, num_topics=10, avg_doc_len=50
    )
    hyper = LDAHyperParams(num_topics=10, alpha=0.1, beta=0.01)
    session = TrainSession(
        corpus, hyper,
        RunConfig(algorithm="zen", num_iterations=30, eval_every=10),
    )

    state = session.init(jax.random.key(0))
    print(f"corpus: {corpus.num_tokens} tokens, llh0 = {session.llh(state):.1f}")

    def report(st, metrics):
        if metrics:
            print(f"iter {int(st.iteration):3d}  llh {metrics['llh']:12.1f}  "
                  f"perplexity {metrics['perplexity']:8.2f}  "
                  f"change_rate {metrics['change_rate']:.3f}")

    state = session.run(state=state, callback=report)

    # top words per learned topic
    n_wk = np.asarray(state.n_wk)
    print("\ntop words per topic:")
    for k in range(hyper.num_topics):
        top = np.argsort(-n_wk[:, k])[:8]
        print(f"  topic {k:2d}: {top.tolist()}")


if __name__ == "__main__":
    main()
