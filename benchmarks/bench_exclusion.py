"""Paper Fig. 9: "converged" token exclusion — change rate of topic
assignments per iteration, active fraction, sampling time, and llh with
vs without exclusion. Also §5.2 delta aggregation: bytes that actually
need to move per iteration (changed tokens only)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.core import LDATrainer, TrainConfig, LDAHyperParams
from repro.core.exclusion import ExclusionConfig
from repro.data import synthetic_lda_corpus


def main(iters: int = 16, start: int = 6):
    corpus, _ = synthetic_lda_corpus(
        4, num_docs=400, num_words=700, num_topics=24, avg_doc_len=60
    )
    hyper = LDAHyperParams(num_topics=24, alpha=0.05, beta=0.01)

    base = LDATrainer(corpus, hyper, TrainConfig(algorithm="zen"))
    excl = LDATrainer(
        corpus, hyper,
        TrainConfig(algorithm="zen",
                    exclusion=ExclusionConfig(enabled=True,
                                              start_iteration=start)),
    )
    sb = base.init_state(jax.random.key(0))
    se = excl.init_state(jax.random.key(0))
    tb = te = 0.0
    for i in range(iters):
        t0 = time.perf_counter(); sb = base.step(sb); tb += time.perf_counter() - t0
        t0 = time.perf_counter(); se = excl.step(se); te += time.perf_counter() - t0
        if i == iters - 1:
            # Fig. 9a: change rate (drives delta aggregation too)
            change = base.change_rate(sb)
            active = float(jnp.mean((se.stale_iters == 0).astype(jnp.float32)))
            row("fig9a_change_rate_final", 0.0, f"rate={change:.3f}")
            row("fig9a_active_fraction_with_exclusion", 0.0,
                f"active={active:.3f}")
    row("fig9b_time_no_exclusion", tb / iters * 1e6, "")
    row("fig9b_time_with_exclusion", te / iters * 1e6,
        f"speedup={tb / te:.2f}")
    lb, le = base.llh(sb), excl.llh(se)
    row("fig9c_llh_no_exclusion", 0.0, f"llh={lb:.1f}")
    row("fig9c_llh_with_exclusion", 0.0,
        f"llh={le:.1f};rel_gap={(lb - le) / abs(lb):.4f}")
    # §5.2 delta aggregation: payload if only changed tokens move
    changed = float(jnp.mean((sb.topic != sb.prev_topic).astype(jnp.float32)))
    full = corpus.num_tokens * 4
    row("sec52_delta_aggregation_bytes", 0.0,
        f"full={full};delta={int(full * changed)};saving={1 - changed:.2%}")


if __name__ == "__main__":
    main()
