"""Latency-mode serving + the async ticket front (DESIGN.md §5.1).

The latency-mode contract:

* the engine's RT-LDA decode is **deterministic** — the same document
  yields bit-identical topic assignments and theta for every bucket
  layout and batch composition, and matches the single-doc
  ``rtlda_infer`` oracle;
* the async front's ticket lifecycle is observable
  (``queued -> admitted -> done``), ``result`` blocks/timeouts/reaps
  correctly, and out-of-order completion works;
* the ``zen_pallas`` frozen-model kernel variant honors the default
  derivation's stability contract: per-slot seeds make its draws
  independent of padding and batch layout (bit-stable), with the kernel
  bit-equal to its pure-jnp oracle (``tests/test_kernels.py``).
"""
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.inference import rtlda_assign, rtlda_infer
from repro.core.types import LDAHyperParams
from repro.serving import FrozenLDAModel, LDAEngine, LDAServeConfig


def _sharp_model(k=4, w=40, weight=100):
    n_wk = np.zeros((w, k), np.int32)
    block = w // k
    for t in range(k):
        n_wk[t * block : (t + 1) * block, t] = weight
    hyper = LDAHyperParams(num_topics=k, alpha=0.1, beta=0.01)
    return FrozenLDAModel(
        n_wk=jnp.asarray(n_wk),
        n_k=jnp.asarray(n_wk.sum(0).astype(np.int32)),
        hyper=hyper,
    )


def _mixed_docs(rng, n, w=40, lo=1, hi=24):
    return [
        rng.integers(0, w, size=rng.integers(lo, hi)).astype(np.int32)
        for _ in range(n)
    ]


def _latency_cfg(**kw):
    kw.setdefault("buckets", (8, 16, 32))
    kw.setdefault("max_batch", 4)
    kw.setdefault("mode", "latency")
    kw.setdefault("rtlda_sweeps", 2)
    return LDAServeConfig(**kw)


# -- RT-LDA engine-path determinism -----------------------------------------

def test_latency_mode_matches_rtlda_oracle():
    """Every served theta equals the single-doc deterministic oracle."""
    model = _sharp_model()
    docs = _mixed_docs(np.random.default_rng(0), 24)
    eng = LDAEngine(model, _latency_cfg(), seed=0)
    thetas = eng.infer_batch(docs)
    for theta, doc in zip(thetas, docs):
        oracle = np.asarray(rtlda_infer(
            model.n_wk, model.n_k, jnp.asarray(doc), model.hyper,
            num_sweeps=2,
        ))
        np.testing.assert_allclose(theta, oracle, atol=1e-6)


def test_latency_mode_deterministic_across_batch_and_padding():
    """Same docs -> bit-identical assignments + thetas, regardless of
    bucket widths, batch composition, submission order, or engine seed."""
    model = _sharp_model()
    docs = _mixed_docs(np.random.default_rng(1), 16)

    def serve(cfg, seed, order):
        eng = LDAEngine(model, cfg, seed=seed)
        uids = [eng.submit(docs[i]) for i in order]
        done = {r.uid: r for r in eng.run_until_done()}
        by_doc = {}
        for i, u in zip(order, uids):
            by_doc[i] = (done[u].z, done[u].theta)
        return by_doc

    base = serve(_latency_cfg(), seed=0, order=list(range(16)))
    variants = [
        serve(_latency_cfg(buckets=(32,), max_batch=16), 7,
              list(range(16))),
        serve(_latency_cfg(buckets=(4, 8, 64), max_batch=2), 3,
              list(reversed(range(16)))),
    ]
    for variant in variants:
        for i in range(16):
            np.testing.assert_array_equal(base[i][0], variant[i][0])
            np.testing.assert_array_equal(base[i][1], variant[i][1])


def test_rtlda_assign_padding_exact():
    """The masked padded decode is bit-identical to the unpadded one."""
    model = _sharp_model()
    rng = np.random.default_rng(2)
    doc = rng.integers(0, 40, size=11).astype(np.int32)
    z0, n_kd0 = rtlda_assign(
        model.n_wk, model.n_k, jnp.asarray(doc),
        jnp.ones((11,), bool), model.hyper, num_sweeps=3,
    )
    padded = np.zeros(32, np.int32)
    padded[:11] = doc
    mask = np.zeros(32, bool)
    mask[:11] = True
    z1, n_kd1 = rtlda_assign(
        model.n_wk, model.n_k, jnp.asarray(padded),
        jnp.asarray(mask), model.hyper, num_sweeps=3,
    )
    np.testing.assert_array_equal(np.asarray(z0), np.asarray(z1)[:11])
    np.testing.assert_array_equal(np.asarray(n_kd0), np.asarray(n_kd1))


def test_latency_mode_ignores_chain_knobs_and_recovers_topics():
    """Per-request chain knobs are inert in latency mode; sharp docs
    still decode to their dominant topic."""
    model = _sharp_model()
    eng = LDAEngine(model, _latency_cfg(), seed=0)
    docs = [np.arange(t * 10, t * 10 + 8, dtype=np.int32) for t in range(4)]
    thetas = eng.infer_batch(
        docs, key=jax.random.key(9), num_sweeps=50, burn_in=5, thin=2
    )
    assert [int(np.argmax(t)) for t in thetas] == [0, 1, 2, 3]
    # one fused decode per non-empty bucket, not one per sweep
    assert eng.sweeps_run == 1


def test_latency_mode_edge_cases():
    model = _sharp_model()
    eng = LDAEngine(model, _latency_cfg(), seed=0)
    thetas = eng.infer_batch([
        np.array([], np.int32),  # empty -> prior
        np.array([1000, -3], np.int32),  # all unknown -> prior
        np.arange(100, dtype=np.int32) % 40,  # over-long -> truncated
    ])
    prior = thetas[0]
    np.testing.assert_allclose(prior, prior[::-1], atol=1e-7)  # symmetric
    np.testing.assert_array_equal(thetas[1], prior)
    np.testing.assert_allclose(thetas[2].sum(), 1.0, atol=1e-3)


# -- async ticket lifecycle --------------------------------------------------

def test_ticket_lifecycle_poll_before_ready():
    model = _sharp_model()
    eng = LDAEngine(
        model,
        LDAServeConfig(buckets=(16,), max_batch=4, num_sweeps=3),
        seed=0,
    )
    ticket = eng.submit_async(np.arange(8, dtype=np.int32))
    assert eng.poll(ticket) == "queued"
    eng.step()  # admits + first sweep (of 3)
    assert eng.poll(ticket) == "admitted"
    eng.step()
    eng.step()
    assert eng.poll(ticket) == "done"
    theta = eng.result(ticket)
    np.testing.assert_allclose(theta.sum(), 1.0, atol=1e-3)
    with pytest.raises(KeyError):  # reaped
        eng.poll(ticket)
    with pytest.raises(KeyError):
        eng.result(ticket)
    with pytest.raises(KeyError):  # never issued
        eng.poll(123456)


def test_result_timeout_and_inline_driving():
    model = _sharp_model()
    eng = LDAEngine(
        model,
        LDAServeConfig(buckets=(16,), max_batch=4, num_sweeps=4),
        seed=0,
    )
    ticket = eng.submit_async(np.arange(6, dtype=np.int32))
    with pytest.raises(TimeoutError):  # not done, no time to drive
        eng.result(ticket, timeout=0)
    # without a ticker, result() drives the engine itself
    theta = eng.result(ticket, timeout=60)
    assert eng.request.__doc__  # api sanity: request() exists
    np.testing.assert_allclose(theta.sum(), 1.0, atol=1e-3)


def test_cancel_reaps_and_unqueues():
    """cancel() drops abandoned tickets: queued ones never decode,
    unknown/reaped ones are a harmless no-op."""
    model = _sharp_model()
    eng = LDAEngine(
        model,
        LDAServeConfig(buckets=(16,), max_batch=1, num_sweeps=3),
        seed=0,
    )
    occupant = eng.submit_async(np.arange(6, dtype=np.int32))
    starved = eng.submit_async(np.arange(6, 12, dtype=np.int32))
    eng.step()  # occupant admitted; starved still queued
    assert eng.poll(starved) == "queued"
    assert eng.cancel(starved) is True
    assert eng.cancel(starved) is False  # already reaped
    assert eng.cancel(999) is False  # never issued
    with pytest.raises(KeyError):
        eng.poll(starved)
    eng.result(occupant, timeout=60)
    # the cancelled request never decoded
    assert eng.docs_done == 1 and not eng.queue


def test_cancel_admitted_evacuates_slot_and_version_pin():
    """A cancelled *admitted* request must not stay a zombie: its slot
    empties immediately, which also releases the bucket's model-version
    pin — the cancel-vs-admission race where a cancelled long chain kept
    blocking post-reload admissions on a maxed-out bucket."""
    model = _sharp_model()
    model2 = _sharp_model(weight=50)
    eng = LDAEngine(
        model,
        LDAServeConfig(buckets=(16,), max_batch=1, num_sweeps=500),
        seed=0,
    )
    zombie = eng.submit_async(np.arange(6, dtype=np.int32))
    eng.step()
    assert eng.poll(zombie) == "admitted"
    eng.reload(model2)
    blocked = eng.submit_async(np.arange(6, 12, dtype=np.int32),
                               num_sweeps=2)
    eng.step()
    # old-version occupant pins the only slot: no cross-version residency
    assert eng.poll(blocked) == "queued"
    assert eng.cancel(zombie) is True
    eng.step()  # slot free -> admitted under the NEW version, same tick
    req = eng.request(blocked)
    assert req.admitted and req.model_version == 1
    eng.result(blocked, timeout=60)
    # the cancelled chain never completed and nothing lingers in-flight
    assert eng.docs_done == 1 and not eng.queue
    assert all(b.num_active == 0 for b in eng._buckets.values())


def test_cancel_race_under_background_ticker():
    """The threaded variant: cancels racing a live ticker's admissions
    never strand slots or tickets — every surviving request completes,
    every cancelled one is gone, and the engine fully drains."""
    model = _sharp_model()
    eng = LDAEngine(
        model,
        LDAServeConfig(buckets=(16,), max_batch=2, num_sweeps=20),
        seed=0,
    )
    eng.start(0.0005)
    try:
        rng = np.random.default_rng(0)
        tickets = [eng.submit_async(d) for d in _mixed_docs(rng, 24, hi=15)]
        stop = threading.Event()

        def cancel_evens():
            for t in tickets[::2]:
                eng.cancel(t)
                time.sleep(0.002)
            stop.set()

        th = threading.Thread(target=cancel_evens)
        th.start()
        thetas = [eng.result(t, timeout=60) for t in tickets[1::2]]
        th.join()
    finally:
        eng.stop()
    assert all(t.shape == (model.num_topics,) for t in thetas)
    for t in tickets[::2]:
        with pytest.raises(KeyError):
            eng.poll(t)
    # drain completely: no zombie occupants left behind by the races
    deadline = time.monotonic() + 30
    while eng._pending() and time.monotonic() < deadline:
        eng.step()
    assert not eng.queue
    assert all(b.num_active == 0 for b in eng._buckets.values())


def test_out_of_order_completion():
    """A later-submitted short chain finishes before an earlier long one;
    results are retrievable in any order."""
    model = _sharp_model()
    eng = LDAEngine(
        model,
        LDAServeConfig(buckets=(16,), max_batch=4, num_sweeps=8),
        seed=0,
    )
    slow = eng.submit_async(np.arange(8, dtype=np.int32), num_sweeps=8)
    fast = eng.submit_async(np.arange(8, 14, dtype=np.int32), num_sweeps=2)
    eng.step()
    eng.step()
    assert eng.poll(fast) == "done"
    assert eng.poll(slow) == "admitted"
    fast_req = eng.request(fast)
    theta_fast = eng.result(fast)
    theta_slow = eng.result(slow, timeout=60)  # drives remaining sweeps
    slow_req_done = eng.docs_done == 2
    assert slow_req_done
    assert fast_req.t_done >= fast_req.t_submit
    np.testing.assert_allclose(theta_fast.sum(), 1.0, atol=1e-3)
    np.testing.assert_allclose(theta_slow.sum(), 1.0, atol=1e-3)


def test_background_ticker_coalesces_requests():
    """submit_async never blocks; the ticker batches whatever arrived
    between ticks and result() just waits."""
    model = _sharp_model()
    eng = LDAEngine(model, _latency_cfg(buckets=(16,), max_batch=8), seed=0)
    eng.start(0.001)
    try:
        tickets = [
            eng.submit_async(doc)
            for doc in _mixed_docs(np.random.default_rng(4), 6, lo=2, hi=15)
        ]
        thetas = [eng.result(t, timeout=120) for t in tickets]
    finally:
        eng.stop()
    for theta in thetas:
        np.testing.assert_allclose(theta.sum(), 1.0, atol=1e-3)
    assert eng.docs_done == 6
    # ticker results match the inline engine bit-for-bit (determinism)
    eng2 = LDAEngine(model, _latency_cfg(buckets=(16,), max_batch=8), seed=9)
    thetas2 = eng2.infer_batch(
        _mixed_docs(np.random.default_rng(4), 6, lo=2, hi=15)
    )
    np.testing.assert_array_equal(np.stack(thetas), thetas2)


def test_submit_async_from_other_threads():
    """The engine lock makes cross-thread submit/result safe."""
    model = _sharp_model()
    eng = LDAEngine(model, _latency_cfg(buckets=(16,), max_batch=8), seed=0)
    eng.start(0.001)
    out = {}

    def client(i):
        doc = np.arange(i, i + 6, dtype=np.int32) % 40
        t = eng.submit_async(doc)
        out[i] = eng.result(t, timeout=120)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(4)]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        eng.stop()
    assert sorted(out) == [0, 1, 2, 3]
    for theta in out.values():
        np.testing.assert_allclose(theta.sum(), 1.0, atol=1e-3)


def test_max_slot_wait_spills_to_wider_bucket():
    """A request starved of its preferred bucket takes a wider free slot
    after max_slot_wait ticks instead of queueing forever behind it."""
    model = _sharp_model()
    eng = LDAEngine(
        model,
        LDAServeConfig(buckets=(8, 32), max_batch=1, num_sweeps=50,
                       max_slot_wait=2),
        seed=0,
    )
    eng.submit(np.arange(6, dtype=np.int32))  # occupies the 8-bucket
    starved = eng.submit_async(np.arange(6, dtype=np.int32))
    eng.step()  # tick 1: starved waits (ticks_waited -> 1)
    eng.step()  # tick 2: waits (ticks_waited -> 2)
    assert eng.poll(starved) == "queued"
    eng.step()  # tick 3: spill into the free 32-bucket
    assert eng.poll(starved) == "admitted"


# -- zen_pallas frozen-model variant ----------------------------------------

def _serve_one(model, doc, key, *, buckets, batch_mates=(), seed=0):
    eng = LDAEngine(
        model,
        LDAServeConfig(buckets=buckets, max_batch=8, num_sweeps=10,
                       algorithm="zen_pallas"),
        seed=seed,
    )
    uid = eng.submit(doc, key=key)
    for mate in batch_mates:
        eng.submit(mate)
    return {r.uid: r for r in eng.run_until_done()}[uid].theta


def test_zen_pallas_padding_and_batch_bit_stable():
    """With per-slot seeds the kernel backend now honors the default
    derivation's stability contract: bucket padding, batch mates, and
    engine seed never change a request's draws (previously it hashed one
    scalar seed with flat batch coordinates, so layout leaked in)."""
    model = _sharp_model()
    rng = np.random.default_rng(5)
    doc = rng.integers(0, 40, size=10).astype(np.int32)
    key = jax.random.key(11)
    alone = _serve_one(model, doc, key, buckets=(16,))
    for theta in (
        _serve_one(model, doc, key, buckets=(32,), seed=2),
        _serve_one(model, doc, key, buckets=(64, 128), seed=3),
        _serve_one(model, doc, key, buckets=(16,), seed=4,
                   batch_mates=_mixed_docs(rng, 5, lo=1, hi=14)),
    ):
        np.testing.assert_array_equal(alone, theta)


def test_zen_pallas_frozen_variant_matches_default_derivation():
    """The frozen kernel samples the same frozen-phi conditional as the
    default dense derivation: on a sharply peaked model both backends
    must decode identical dominant topics, and the kernel's theta stays
    within posterior-mean tolerance of the default's."""
    model = _sharp_model()
    rng = np.random.default_rng(6)
    docs, doms = [], []
    for i in range(8):
        t = i % 4
        docs.append(
            rng.integers(t * 10, (t + 1) * 10, size=15).astype(np.int32)
        )
        doms.append(t)
    thetas = {}
    for algorithm in ("zen", "zen_pallas"):
        eng = LDAEngine(
            model,
            LDAServeConfig(buckets=(16, 32), max_batch=8, num_sweeps=15,
                           algorithm=algorithm),
            seed=3,
        )
        thetas[algorithm] = eng.infer_batch(docs)
        assert [int(np.argmax(t)) for t in thetas[algorithm]] == doms
    for a, b in zip(thetas["zen"], thetas["zen_pallas"]):
        assert np.abs(a - b).sum() < 0.15


def test_latency_request_diagnostics_and_timestamps():
    model = _sharp_model()
    eng = LDAEngine(model, _latency_cfg(buckets=(8,)), seed=0)
    t0 = time.monotonic()
    ticket = eng.submit_async(np.arange(5, dtype=np.int32))
    req = eng.request(ticket)
    theta = eng.result(ticket, timeout=60)
    assert req.done and req.z is not None and req.z.shape == (5,)
    assert t0 <= req.t_submit <= req.t_done
    np.testing.assert_allclose(theta, req.theta)
