from repro.data.corpus import (  # noqa: F401
    load_libsvm,
    save_libsvm,
    synthetic_corpus,
    synthetic_lda_corpus,
)
