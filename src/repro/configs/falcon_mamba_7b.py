"""falcon-mamba-7b [ssm]: 64L mamba1 blocks (attn-free) d_model=4096,
ssm_state=16, vocab=65024. [arXiv:2410.05355; unverified]

Attention-free -> long_500k RUNS with O(1) recurrent state.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,  # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    ssm=SSMConfig(version=1, state_dim=16, conv_dim=4, expand=2),
    tie_embeddings=True,
)
