"""SparseLDA + LightLDA baselines on the shared substrate (paper §7.2)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LDATrainer, TrainConfig
from repro.core.baselines import build_doc_index, lightlda_sweep, sparselda_sweep
from repro.core.init import random_init


def test_doc_index(key, tiny_corpus):
    idx = build_doc_index(tiny_corpus)
    docs = np.asarray(tiny_corpus.doc)
    np.testing.assert_array_equal(
        np.asarray(idx.lengths), np.bincount(docs, minlength=tiny_corpus.num_docs)
    )
    # every doc's slice points at its own tokens
    tok = np.asarray(idx.token_of)
    off = np.asarray(idx.offsets)
    for d in [0, 3, tiny_corpus.num_docs - 1]:
        sl = tok[off[d] : off[d + 1]]
        assert (docs[sl] == d).all()


def test_sparselda_valid_and_converges(key, tiny_corpus, tiny_hyper):
    tr = LDATrainer(tiny_corpus, tiny_hyper, TrainConfig(algorithm="sparselda"))
    st = tr.init_state(key)
    llh0 = tr.llh(st)
    for _ in range(8):
        st = tr.step(st)
    st.check_invariants(tiny_corpus)
    assert tr.llh(st) > llh0


def test_lightlda_valid_and_converges(key, tiny_corpus, tiny_hyper):
    tr = LDATrainer(tiny_corpus, tiny_hyper,
                    TrainConfig(algorithm="lightlda", num_mh=4))
    st = tr.init_state(key)
    llh0 = tr.llh(st)
    for _ in range(8):
        st = tr.step(st)
    st.check_invariants(tiny_corpus)
    assert tr.llh(st) > llh0


def test_all_algorithms_same_stationary_direction(key, tiny_corpus, tiny_hyper):
    """All samplers target Eq. 3: after equal iterations the llh values land
    in a common band (coarse cross-validation of the baselines)."""
    finals = {}
    for alg in ("zen", "sparselda", "lightlda"):
        tr = LDATrainer(tiny_corpus, tiny_hyper, TrainConfig(algorithm=alg))
        st = tr.init_state(key)
        for _ in range(10):
            st = tr.step(st)
        finals[alg] = tr.llh(st)
    vals = np.asarray(list(finals.values()))
    spread = (vals.max() - vals.min()) / abs(vals.mean())
    assert spread < 0.08, finals
