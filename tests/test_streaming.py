"""Streaming subsystem: corpus sources, windowed online training,
checkpoint resume, and hot model reload in serving (DESIGN.md §7)."""
import dataclasses
import os
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import LDAHyperParams
from repro.data import save_libsvm, synthetic_corpus, synthetic_lda_corpus
from repro.data.stream import (
    DriftSource,
    LibsvmStreamSource,
    ReplaySource,
    make_source,
)
from repro.serving import FrozenLDAModel, LDAEngine, LDAServeConfig
from repro.train.checkpoint import save_lda_model
from repro.train.online import StreamingSession
from repro.train.session import RunConfig, TrainSession


def _stream_cfg(**kw):
    kw.setdefault("num_iterations", 0)
    kw.setdefault("window_docs", 10)
    kw.setdefault("window_sweeps", 1)
    return RunConfig(**kw)


# ---------------------------------------------------------------------------
# corpus sources
# ---------------------------------------------------------------------------

def test_replay_source_partitions_corpus(tiny_corpus):
    src = ReplaySource(tiny_corpus, window_docs=12, epochs=1)
    wins = list(src.windows())
    assert len(wins) == src.windows_per_epoch == 4  # ceil(40 / 12)
    assert [w.index for w in wins] == [0, 1, 2, 3]
    # windows cover the corpus exactly once, doc ids are window-local
    assert sum(w.corpus.num_docs for w in wins) == tiny_corpus.num_docs
    assert sum(w.corpus.num_tokens for w in wins) == tiny_corpus.num_tokens
    seen = np.zeros(tiny_corpus.num_tokens, np.int32)
    for w in wins:
        assert w.corpus.num_words == tiny_corpus.num_words
        assert int(w.corpus.doc.min()) == 0
        assert int(w.corpus.doc.max()) < w.corpus.num_docs
        seen[w.token_index] += 1
        # token_index maps window tokens back to source edges exactly
        np.testing.assert_array_equal(
            np.asarray(w.corpus.word),
            np.asarray(tiny_corpus.word)[w.token_index],
        )
    np.testing.assert_array_equal(seen, 1)


def test_replay_source_epochs_reuse_uids(tiny_corpus):
    src = ReplaySource(tiny_corpus, window_docs=15, epochs=2)
    wins = list(src.windows())
    assert len(wins) == src.num_windows == 6
    assert [w.uid for w in wins] == ["w0", "w1", "w2"] * 2
    assert [w.index for w in wins] == list(range(6))  # stream index advances
    # resume contract: start=k yields the identical tail
    tail = list(src.windows(start=4))
    assert [w.index for w in tail] == [4, 5]
    np.testing.assert_array_equal(
        np.asarray(tail[0].corpus.word), np.asarray(wins[4].corpus.word)
    )


def test_libsvm_stream_source_windows_and_resume():
    c = synthetic_corpus(5, num_docs=17, num_words=25, avg_doc_len=6)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "c.libsvm")
        save_libsvm(c, path)
        src = LibsvmStreamSource(path, window_docs=5, num_words=25)
        wins = list(src.windows())
        assert [w.corpus.num_docs for w in wins] == [5, 5, 5, 2]
        assert all(w.corpus.num_words == 25 for w in wins)
        assert sum(w.corpus.num_tokens for w in wins) == c.num_tokens
        # resume fast-forwards without re-reading earlier windows
        tail = list(src.windows(start=2))
        assert [w.index for w in tail] == [2, 3]
        np.testing.assert_array_equal(
            np.asarray(tail[0].corpus.word), np.asarray(wins[2].corpus.word)
        )
    with pytest.raises(ValueError, match="num_words"):
        LibsvmStreamSource("x", window_docs=5, num_words=0)


def test_drift_source_deterministic_resume():
    src = DriftSource(seed=7, window_docs=6, num_windows=5, num_words=30)
    a = list(src.windows())
    assert len(a) == 5
    b = list(src.windows(start=3))  # replays the phi chain silently
    assert [w.index for w in b] == [3, 4]
    for wa, wb in zip(a[3:], b):
        np.testing.assert_array_equal(
            np.asarray(wa.corpus.word), np.asarray(wb.corpus.word)
        )
        np.testing.assert_array_equal(
            np.asarray(wa.corpus.doc), np.asarray(wb.corpus.doc)
        )
    # the stream actually drifts: consecutive windows differ
    assert not np.array_equal(
        np.asarray(a[0].corpus.word), np.asarray(a[1].corpus.word)
    )


def test_make_source_specs(tiny_corpus):
    s = make_source("replay", 10, corpus=tiny_corpus, epochs=2)
    assert isinstance(s, ReplaySource) and s.epochs == 2
    s = make_source("drift:11", 8, num_words=50, num_windows=3)
    assert isinstance(s, DriftSource) and s.seed == 11
    s = make_source("libsvm:/tmp/x.libsvm", 8, num_words=50)
    assert isinstance(s, LibsvmStreamSource) and s.path == "/tmp/x.libsvm"
    with pytest.raises(ValueError, match="unknown stream_source"):
        make_source("kafka:topic", 8)
    with pytest.raises(ValueError, match="needs a corpus"):
        make_source("replay", 8)


# ---------------------------------------------------------------------------
# windowed online training
# ---------------------------------------------------------------------------

def test_windowed_replay_matches_batch_trend(tiny_corpus, tiny_hyper):
    """Acceptance: decay=0 replay rotation reproduces the batch
    SingleBoxPlan perplexity trend — same corpus, same total sweep
    budget, full-corpus perplexity within a trend-level band."""
    iters = 10
    batch = TrainSession(
        tiny_corpus, tiny_hyper,
        RunConfig(algorithm="zen", num_iterations=iters),
    )
    state = batch.init(jax.random.key(0))
    ppl0 = batch.perplexity(state)
    state = batch.run(state=state)
    ppl_batch = batch.perplexity(state)
    assert ppl_batch < ppl0  # batch run converges on this corpus

    src = ReplaySource(tiny_corpus, window_docs=10, epochs=iters)
    sess = StreamingSession(src, tiny_hyper, _stream_cfg(algorithm="zen"))
    metrics = []
    sess.run(jax.random.key(0), callback=lambda s, m: metrics.append(m))
    assert sess.windows_done == src.num_windows
    ppl_stream = sess.full_perplexity()
    # same trend: converged well below the random-init level, and within
    # a band of the batch endpoint (different sweep order => not equal)
    assert ppl_stream < 0.6 * ppl0
    assert abs(ppl_stream - ppl_batch) / ppl_batch < 0.15
    # per-window perplexity improves epoch over epoch
    first_epoch = np.mean([m["perplexity"] for m in metrics[:4]])
    last_epoch = np.mean([m["perplexity"] for m in metrics[-4:]])
    assert last_epoch < first_epoch


def test_stream_counts_stay_consistent(tiny_corpus, tiny_hyper):
    """decay=0 replay: after any number of windows the global counts hold
    exactly the corpus tokens seen so far, and n_k == n_wk.sum(0)."""
    src = ReplaySource(tiny_corpus, window_docs=10, epochs=2)
    sess = StreamingSession(src, tiny_hyper, _stream_cfg())
    tokens_seen = 0
    for w in src.windows():
        sess.run_window(w)
        if w.index < src.windows_per_epoch:
            tokens_seen += w.corpus.num_tokens
        nwk = np.asarray(sess.n_wk)
        np.testing.assert_array_equal(np.asarray(sess.n_k), nwk.sum(0))
        assert nwk.sum() == tokens_seen


def test_decay_mode_forgets(tiny_hyper):
    src = DriftSource(seed=1, window_docs=8, num_windows=4, num_words=40,
                      num_topics=6)
    cfg = _stream_cfg(window_docs=8, decay=0.5, window_sweeps=2)
    sess = StreamingSession(src, tiny_hyper, cfg)
    sess.run(jax.random.key(2))
    assert not sess._retain and not sess._retained  # nothing retained
    nwk = np.asarray(sess.n_wk)
    np.testing.assert_array_equal(np.asarray(sess.n_k), nwk.sum(0))
    # heavy decay: resident mass is far below the 4-window token total,
    # bounded by window + geometric tail of earlier windows
    per_window = src.window_docs * src.avg_doc_len
    assert nwk.sum() < 2.5 * per_window


def test_streaming_session_validation(tiny_corpus, tiny_hyper):
    src = ReplaySource(tiny_corpus, window_docs=10)
    with pytest.raises(ValueError, match="single-box"):
        StreamingSession(src, tiny_hyper, _stream_cfg(mesh_shape=(1, 2)))
    with pytest.raises(ValueError, match="decay"):
        StreamingSession(src, tiny_hyper, _stream_cfg(decay=1.0))
    with pytest.raises(ValueError, match="window_sweeps"):
        StreamingSession(src, tiny_hyper, _stream_cfg(window_sweeps=0))


# ---------------------------------------------------------------------------
# mid-stream checkpoint resume
# ---------------------------------------------------------------------------

def _drift_run(cfg, tiny_hyper):
    src = DriftSource(seed=9, window_docs=8, num_windows=6, num_words=40,
                      num_topics=6)
    sess = StreamingSession(src, tiny_hyper, cfg)
    sess.run(jax.random.key(5))
    return sess


def test_checkpoint_resume_matches_uninterrupted_drift(tiny_hyper):
    """Kill a windowed drift run after window 3, resume from the elastic
    checkpoint, and land bit-identical to an uninterrupted run."""
    full = _drift_run(_stream_cfg(window_docs=8, decay=0.1), tiny_hyper)
    assert full.windows_done == 6
    with tempfile.TemporaryDirectory() as td:
        cfg = _stream_cfg(window_docs=8, decay=0.1,
                          train_checkpoint_dir=td, train_checkpoint_every=1)
        killed = _drift_run(
            dataclasses.replace(cfg, num_iterations=3), tiny_hyper
        )
        assert killed.windows_done == 3
        resumed = _drift_run(cfg, tiny_hyper)
    assert resumed.windows_done == 6
    np.testing.assert_array_equal(
        np.asarray(resumed.n_wk), np.asarray(full.n_wk)
    )
    np.testing.assert_array_equal(
        np.asarray(resumed.n_k), np.asarray(full.n_k)
    )


def test_checkpoint_resume_restores_retained_assignments(
    tiny_corpus, tiny_hyper
):
    """Rotation regime: the retained per-window z survives the
    checkpoint, so a resumed replay run is bit-identical too."""
    def run(cfg, limit=None):
        src = ReplaySource(tiny_corpus, window_docs=10, epochs=2)
        c = cfg if limit is None else dataclasses.replace(
            cfg, num_iterations=limit
        )
        sess = StreamingSession(src, tiny_hyper, c)
        sess.run(jax.random.key(3))
        return sess

    full = run(_stream_cfg())
    assert full.windows_done == 8
    with tempfile.TemporaryDirectory() as td:
        cfg = _stream_cfg(train_checkpoint_dir=td, train_checkpoint_every=1)
        killed = run(cfg, limit=5)  # mid-epoch-2: w0 already revisited
        assert killed.windows_done == 5
        resumed = run(cfg)
    assert resumed.windows_done == 8
    np.testing.assert_array_equal(
        np.asarray(resumed.n_wk), np.asarray(full.n_wk)
    )
    assert sorted(resumed._retained) == sorted(full._retained)
    for uid in full._retained:
        np.testing.assert_array_equal(resumed._retained[uid],
                                      full._retained[uid])
    # and the reassembled full-corpus state matches bit-for-bit
    assert resumed.full_perplexity() == pytest.approx(full.full_perplexity())


# ---------------------------------------------------------------------------
# hot model reload in serving
# ---------------------------------------------------------------------------

def _two_models(seed=0, num_words=50, k=5):
    corpus, _ = synthetic_lda_corpus(seed, 30, num_words, k, 25)
    hyper = LDAHyperParams(num_topics=k)
    from repro.core import counts as counts_lib

    z = jax.random.randint(jax.random.key(seed), (corpus.num_tokens,), 0, k,
                           dtype=jnp.int32)
    n_wk, _n_kd, n_k = counts_lib.build_counts(
        corpus.word, corpus.doc, z, corpus.num_words, corpus.num_docs, k
    )
    m0 = FrozenLDAModel(n_wk=n_wk, n_k=n_k, hyper=hyper)
    m1 = FrozenLDAModel(n_wk=n_wk * 3, n_k=n_k * 3, hyper=hyper)
    return m0, m1, corpus


def test_reload_version_tags_and_monotonicity():
    m0, m1, corpus = _two_models()
    eng = LDAEngine(m0, LDAServeConfig(buckets=(32,), max_batch=4,
                                       num_sweeps=2))
    assert eng.model_version == 0
    assert eng.reload(m1) == 1
    assert eng.model_version == 1 and eng.model is m1
    with pytest.raises(ValueError, match="must increase"):
        eng.reload(m0, version=1)
    assert eng.reload(m0, version=7) == 7


def test_inflight_finishes_on_admitted_model():
    """A request in flight across reload() completes bit-identically to
    an engine that never reloaded — it decodes under the model (and
    version) it was admitted for."""
    m0, m1, corpus = _two_models()
    from repro.serving import docs_from_corpus

    doc = docs_from_corpus(corpus)[0]
    cfg = LDAServeConfig(buckets=(64,), max_batch=2, num_sweeps=6)
    ref = LDAEngine(m0, cfg, seed=3)  # never reloads
    t_ref = ref.submit_async(doc)
    theta_ref = ref.result(t_ref)

    eng = LDAEngine(m0, cfg, seed=3)
    t0 = eng.submit_async(doc)
    eng.step()  # admit + first sweep: now in flight
    assert eng.poll(t0) == "admitted"
    eng.reload(m1)
    t1 = eng.submit_async(doc)  # queued behind the pinned old bucket
    r0, r1 = eng.request(t0), eng.request(t1)
    theta0 = eng.result(t0)
    theta1 = eng.result(t1)
    assert r0.model_version == 0 and r1.model_version == 1
    np.testing.assert_array_equal(theta0, theta_ref)  # old model, bit-equal
    assert not np.allclose(theta1, theta0)  # new model actually serves


@pytest.mark.parametrize("mode", ["throughput", "latency"])
def test_reload_atomic_under_background_ticker(mode):
    """Acceptance: a live engine under a background ticker completes
    every in-flight ticket across an atomic reload, with monotonically
    non-decreasing version tags in submission order and both versions
    observed."""
    m0, m1, corpus = _two_models(seed=2)
    from repro.serving import docs_from_corpus

    docs = docs_from_corpus(corpus)
    # one bucket => FIFO admission, so version tags must be monotonic in
    # submission order (across buckets only per-bucket order is FIFO)
    cfg = LDAServeConfig(buckets=(64,), max_batch=4, num_sweeps=4,
                         mode=mode, rtlda_sweeps=2)
    eng = LDAEngine(m0, cfg, seed=1)
    eng.start(0.001)
    try:
        tickets = []
        for i, d in enumerate(docs):
            tickets.append(eng.submit_async(d))
            if i == len(docs) // 2:
                eng.reload(m1)
            time.sleep(0.0005)
        reqs = [eng.request(t) for t in tickets]
        thetas = [eng.result(t, timeout=60) for t in tickets]
    finally:
        eng.stop()
    # zero dropped / errored tickets
    assert len(thetas) == len(docs)
    assert all(th is not None and np.isfinite(th).all() for th in thetas)
    versions = [r.model_version for r in reqs]
    assert all(v in (0, 1) for v in versions)
    assert versions == sorted(versions)  # monotonic in submission order
    assert versions[0] == 0 and versions[-1] == 1  # both models served
    assert eng.reloads == 1


def test_watch_checkpoint_dir_hot_reloads():
    m0, m1, _corpus = _two_models(seed=4)
    with tempfile.TemporaryDirectory() as td:
        save_lda_model(td, np.asarray(m0.n_wk), np.asarray(m0.n_k),
                       m0.hyper, step=1)
        eng = LDAEngine(m0, LDAServeConfig(buckets=(32,), max_batch=2))
        eng.watch_checkpoint_dir(td, period=0.02, initial_step=1)
        try:
            time.sleep(0.08)
            assert eng.model_version == 0  # step 1 already served
            save_lda_model(td, np.asarray(m1.n_wk), np.asarray(m1.n_k),
                           m1.hyper, step=2)
            deadline = time.monotonic() + 10.0
            while eng.model_version == 0 and time.monotonic() < deadline:
                time.sleep(0.02)
        finally:
            eng.stop_watching()
        assert eng.model_version == 1
        np.testing.assert_array_equal(np.asarray(eng.model.n_wk),
                                      np.asarray(m1.n_wk))
        # idempotent stop
        eng.stop_watching()


# ---------------------------------------------------------------------------
# the live pipeline, in-process: stream trainer writing, engine following
# ---------------------------------------------------------------------------

def test_live_pipeline_stream_to_follow(tiny_hyper):
    """Streaming smoke (CI gate): drift source → 3 windows with model
    checkpoints → a serving engine under a background ticker follows the
    checkpoint dir across the swaps with zero dropped tickets."""
    src = DriftSource(seed=12, window_docs=10, num_windows=3, num_words=40,
                      num_topics=6)
    with tempfile.TemporaryDirectory() as td:
        cfg = _stream_cfg(window_docs=10, decay=0.05,
                          checkpoint_dir=td, checkpoint_every=1)
        sess = StreamingSession(src, tiny_hyper, cfg)
        # commit window 0's model first so the engine has one to start on
        sess.run_window(next(src.windows()))
        sess.save_model()
        eng = LDAEngine(
            FrozenLDAModel.from_checkpoint(td),
            LDAServeConfig(buckets=(32, 64), max_batch=4, num_sweeps=3),
        )
        eng.start(0.001)
        eng.watch_checkpoint_dir(td, period=0.02, initial_step=1)
        stop = threading.Event()
        tickets, t_lock = [], threading.Lock()
        rng = np.random.default_rng(0)

        def client():
            while not stop.is_set():
                doc = rng.integers(0, 40, size=12).astype(np.int32)
                with t_lock:
                    tickets.append(eng.submit_async(doc))
                time.sleep(0.002)

        t = threading.Thread(target=client)
        t.start()
        try:
            for w in src.windows(start=1):  # windows 1, 2
                sess.run_window(w)
                sess.save_model()
            deadline = time.monotonic() + 20.0
            while eng.model_version < 2 and time.monotonic() < deadline:
                time.sleep(0.02)
        finally:
            stop.set()
            t.join()
            with t_lock:
                reqs = [eng.request(tk) for tk in tickets]
                thetas = [eng.result(tk, timeout=60) for tk in tickets]
            eng.stop_watching()
            eng.stop()
    assert sess.windows_done == 3
    assert eng.model_version == 2  # followed both new checkpoints
    # zero dropped / errored tickets across both swaps
    assert len(thetas) == len(tickets) and len(tickets) > 0
    assert all(np.isfinite(th).all() for th in thetas)
    versions = [r.model_version for r in reqs]
    assert versions == sorted(versions)
    assert versions[-1] >= 1  # requests decoded under a reloaded model


# ---------------------------------------------------------------------------
# EOF-truncated final window: exact doc-cursor resume (libsvm tailing)
# ---------------------------------------------------------------------------

def test_libsvm_eof_truncated_window_kill_and_resume():
    """Kill a libsvm stream run whose final window was truncated at EOF
    (7 docs, window_docs=5 -> [5, 2]), append 4 more documents, resume.
    The doc cursor — not ``windows_done * window_docs`` — decides where
    reading restarts, so the resumed run reads exactly from doc 7:
    nothing re-read, nothing skipped."""
    from repro.core.types import LDAHyperParams

    c1 = synthetic_corpus(3, num_docs=7, num_words=25, avg_doc_len=6)
    c2 = synthetic_corpus(4, num_docs=4, num_words=25, avg_doc_len=6)
    hyper = LDAHyperParams(num_topics=6)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "stream.libsvm")
        save_libsvm(c1, path)
        ckpt = os.path.join(td, "ckpt")
        cfg = _stream_cfg(window_docs=5, train_checkpoint_dir=ckpt,
                          train_checkpoint_every=1)

        src = LibsvmStreamSource(path, window_docs=5, num_words=25)
        killed = StreamingSession(src, hyper, cfg)
        killed.run(jax.random.key(2))
        assert killed.windows_done == 2
        assert killed.docs_consumed == 7  # final window held only 2 docs

        # the stream grows: 4 more documents arrive at the tail
        tmp = os.path.join(td, "append.libsvm")
        save_libsvm(c2, tmp)
        with open(tmp) as f_in, open(path, "a") as f_out:
            f_out.write(f_in.read())

        # source-level resume contract: the doc cursor reads doc 7
        # onward exactly; the old window arithmetic (start * window_docs
        # = 10) would have silently skipped three appended documents
        src = LibsvmStreamSource(path, window_docs=5, num_words=25)
        wins = list(src.windows(start=2, start_docs=7))
        assert [w.index for w in wins] == [2]
        assert wins[0].corpus.num_docs == 4
        np.testing.assert_array_equal(
            np.bincount(np.asarray(wins[0].corpus.word), minlength=25),
            np.bincount(np.asarray(c2.word), minlength=25),
        )
        naive = list(
            LibsvmStreamSource(path, window_docs=5,
                               num_words=25).windows(start=2)
        )
        assert sum(w.corpus.num_docs for w in naive) == 1  # skips 10

        # session-level: resume from the elastic checkpoint and consume
        # the appended tail, once
        src = LibsvmStreamSource(path, window_docs=5, num_words=25)
        resumed = StreamingSession(src, hyper, cfg)
        resumed.run(jax.random.key(2))
        assert resumed.windows_done == 3
        assert resumed.docs_consumed == 11
        # counts fold every consumed token in exactly once
        assert int(np.asarray(resumed.n_wk).sum()) \
            == c1.num_tokens + c2.num_tokens
        np.testing.assert_array_equal(
            np.asarray(resumed.n_k),
            np.asarray(resumed.n_wk).sum(axis=0),
        )


def test_watcher_surfaces_truncated_checkpoint_error():
    """A committed-but-corrupt checkpoint (truncated leaf) must not be
    silently mistaken for an empty directory: the watcher retries up to
    ``max_failures`` with logged warnings, gives up, keeps the serving
    model untouched, and surfaces the error via ``watch_error`` /
    ``stop_watching()``."""
    m0, _m1, _corpus = _two_models(seed=6)
    with tempfile.TemporaryDirectory() as td:
        save_lda_model(td, np.asarray(m0.n_wk), np.asarray(m0.n_k),
                       m0.hyper, step=2)
        # truncate a leaf: the step dir stays COMMITTED but unloadable
        leaf = os.path.join(td, "step_00000002", "leaf_00000.npy")
        with open(leaf, "r+b") as f:
            f.truncate(8)

        eng = LDAEngine(m0, LDAServeConfig(buckets=(32,), max_batch=2))
        eng.watch_checkpoint_dir(td, period=0.01, max_failures=3)
        deadline = time.monotonic() + 10.0
        while eng._watcher.is_alive() and time.monotonic() < deadline:
            time.sleep(0.01)
        assert not eng._watcher.is_alive()  # gave up, did not spin
        assert eng._watcher.failures == 3
        assert eng.model_version == 0 and eng.reloads == 0
        err = eng.watch_error
        assert isinstance(err, Exception)
        assert eng.stop_watching() is err  # surfaced on shutdown too
