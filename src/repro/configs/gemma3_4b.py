"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144,
5:1 local:global sliding-window pattern, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

long_500k RUNS for this arch: 29/34 layers are 1024-token sliding window
(bounded KV), only the 5 global layers carry full-length KV (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    sliding_window=1024,
    local_global_pattern=5,  # 5 local then 1 global
    rope_theta=10000.0,
    rope_theta_global=1000000.0,
    tie_embeddings=True,
)
