"""Fault-tolerant training loop (shared by LM and LDA drivers).

Production behaviors implemented:
  * periodic checksummed checkpoints + resume-from-latest on start
  * SIGTERM/SIGINT -> checkpoint-then-exit (preemption handling)
  * per-step retry with exponential backoff (transient failures); after
    ``max_retries`` the loop restores the last checkpoint and continues
    (node-failure path: a re-scheduled job does exactly this)
  * straggler mitigation hook: step-time EWMA + slow-step log, and the
    LDA path's static token-balanced partitioning (``core.graph``) plus
    uniform padding bounds per-device work by construction
"""
from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Any, Callable, Dict, Optional

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopConfig:
    num_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    max_retries: int = 3
    log_every: int = 10
    slow_step_factor: float = 2.0  # straggler flag: step > factor * ewma


class TrainLoop:
    def __init__(
        self,
        step_fn: Callable[[Any], Any],  # state -> (state, metrics)
        loop_cfg: LoopConfig,
        checkpoint_tree_fn: Callable[[Any], Any] = lambda s: s,
        restore_fn: Optional[Callable[[Any, Any], Any]] = None,
        metadata_fn: Callable[[Any], Dict] = lambda s: {},
    ):
        self.step_fn = step_fn
        self.cfg = loop_cfg
        self.checkpoint_tree_fn = checkpoint_tree_fn
        self.restore_fn = restore_fn
        self.metadata_fn = metadata_fn
        self.manager = None
        if loop_cfg.checkpoint_dir:
            from repro.train.checkpoint import CheckpointManager

            self.manager = CheckpointManager(loop_cfg.checkpoint_dir)
        self._stop = False

    def _install_signals(self):
        def handler(signum, frame):
            log.warning("signal %s: checkpoint-and-stop requested", signum)
            self._stop = True

        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass  # not in main thread (tests)

    def maybe_restore(self, state: Any) -> tuple:
        """(state, start_step) — resume from the newest valid checkpoint."""
        if self.manager is None or self.restore_fn is None:
            return state, 0
        tree = self.checkpoint_tree_fn(state)
        got = self.manager.restore_latest(tree)
        if got is None:
            return state, 0
        restored_tree, meta, step = got
        log.info("resuming from checkpoint step %d", step)
        return self.restore_fn(state, restored_tree), step

    def run(self, state: Any) -> Any:
        self._install_signals()
        state, start = self.maybe_restore(state)
        ewma = None
        step = start
        while step < self.cfg.num_steps and not self._stop:
            t0 = time.time()
            retries = 0
            while True:
                try:
                    state, metrics = self.step_fn(state)
                    break
                except Exception as e:  # transient failure path
                    retries += 1
                    if retries > self.cfg.max_retries:
                        if self.manager is not None and self.restore_fn:
                            log.error(
                                "step %d failed %d times (%s); restoring "
                                "last checkpoint", step, retries, e,
                            )
                            got = self.manager.restore_latest(
                                self.checkpoint_tree_fn(state)
                            )
                            if got is not None:
                                state = self.restore_fn(state, got[0])
                                step = got[2]
                                retries = 0
                                continue
                        raise
                    log.warning("step %d retry %d after %s", step, retries, e)
                    time.sleep(min(2.0 ** retries, 30.0))
            dt = time.time() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > self.cfg.slow_step_factor * ewma and step > start + 3:
                log.warning(
                    "straggling step %d: %.2fs vs ewma %.2fs", step, dt, ewma
                )
            step += 1
            if self.cfg.log_every and step % self.cfg.log_every == 0:
                log.info("step %d metrics %s (%.3fs)", step, metrics, dt)
            if (
                self.manager is not None
                and self.cfg.checkpoint_every
                and step % self.cfg.checkpoint_every == 0
            ):
                self.manager.save(
                    step, self.checkpoint_tree_fn(state),
                    self.metadata_fn(state),
                )
        if self._stop and self.manager is not None:
            self.manager.save(
                step, self.checkpoint_tree_fn(state), self.metadata_fn(state)
            )
        return state
