"""PRNG helpers."""
from __future__ import annotations

import hashlib

import jax


def fold_in_str(key: jax.Array, name: str) -> jax.Array:
    """Deterministically fold a string into a PRNG key."""
    h = int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "little")
    return jax.random.fold_in(key, h)


def split_like(key: jax.Array, tree):
    """Split a key into a pytree of keys with the same structure as ``tree``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))
