"""Cross-backend mesh/single-box parity harness (subprocess: own devices).

For EVERY registered backend with ``supports_shard_map`` this asserts, on a
2-device CPU mesh (``--xla_force_host_platform_device_count=2``) against a
single-box run started from the SAME initial assignment:

* count conservation after every distributed iteration (sum N_k == E and
  the N_wk / N_kd column sums equal N_k after the sync step);
* a non-increasing-perplexity trend on both paths (llh improves over the
  run from the shared starting point, and the two paths land in a common
  band after equal iterations);
* replay determinism: re-running the same jitted step from the same init
  yields bit-identical N_wk / N_k (same executable => same counts);
* for the deterministic Gumbel-max backends (zen_dense, zen_pallas), exact
  N_wk / N_k equality between the shard_map step and a host-side per-cell
  emulation of the paper's workflow (same keys, same local views, delta
  merge by hand) — the cell semantics ARE the spec.

The backend list is read from the registry at collection, so a newly
registered mesh-capable algorithm is covered with zero test changes.
"""
import pytest

from helpers import run_with_devices

from repro import algorithms

MESH_BACKENDS = [
    n for n in algorithms.registered() if algorithms.get(n).supports_shard_map
]
GUMBEL_EXACT = ["zen_dense", "zen_pallas"]

COMMON = """
import warnings; warnings.filterwarnings('ignore')
import jax, jax.numpy as jnp, numpy as np
from repro import algorithms
from repro.data import synthetic_lda_corpus
from repro.core.types import CGSState, LDAHyperParams
from repro.core.graph import grid_partition
from repro.core import counts as counts_lib
from repro.launch.mesh import make_mesh
from repro.core.distributed import (DistConfig, init_dist_state,
                                    make_dist_step, resolve_dist_row_pads)

corpus, _ = synthetic_lda_corpus(0, num_docs=50, num_words=80, num_topics=8,
                                 avg_doc_len=30)
hyper = LDAHyperParams(num_topics=8, alpha=0.1, beta=0.05)
K = hyper.num_topics

mesh = make_mesh((1, 2), ('data', 'model'))
grid = grid_partition(corpus, 1, 2)
E = int(grid.mask.sum())
assert E == corpus.num_tokens

# one shared initial assignment: draw per-token topics on the grid, then
# transfer them to corpus token order via the (word, doc) key matching of
# the elastic-rescale test (tokens of one edge are exchangeable)
rng0 = np.random.default_rng(0)
init_grid = np.zeros(grid.word.shape, np.int32)
init_grid[grid.mask] = rng0.integers(0, K, size=E).astype(np.int32)

def inverse_perm(perm, padded_size):
    inv = np.full(padded_size, -1, np.int64)
    inv[perm] = np.arange(perm.shape[0])
    return inv

inv_w = inverse_perm(grid.word_perm, grid.num_words_padded)
inv_d = inverse_perm(grid.doc_perm, grid.num_docs_padded)
gw = inv_w[grid.word[grid.mask]]; gd = inv_d[grid.doc[grid.mask]]
key_grid = gw * 10**6 + gd
cw = np.asarray(corpus.word); cd = np.asarray(corpus.doc)
key_corpus = cw * 10**6 + cd
np.testing.assert_array_equal(np.sort(key_grid), np.sort(key_corpus))
z_corpus = np.zeros(E, np.int32)
z_corpus[np.argsort(key_corpus, kind='stable')] = \
    init_grid[grid.mask][np.argsort(key_grid, kind='stable')]

def single_box_state(key):
    z = jnp.asarray(z_corpus)
    n_wk, n_kd, n_k = counts_lib.build_counts(
        corpus.word, corpus.doc, z, corpus.num_words, corpus.num_docs, K)
    zeros = jnp.zeros((E,), jnp.int32)
    return CGSState(topic=z, prev_topic=z, n_wk=n_wk, n_kd=n_kd, n_k=n_k,
                    rng=key, iteration=jnp.int32(0),
                    stale_iters=zeros, same_count=zeros)

# ONE evaluator for both paths: the mesh state's counts mapped back to
# corpus ids (the dist llh uses the padded vocab in W*beta, so comparing
# raw dist llh against the single-box llh would mix two metrics)
from repro.core.likelihood import predictive_llh

def eval_dist(dist_state):
    n_wk = jnp.asarray(np.asarray(dist_state.n_wk)[grid.word_perm])
    n_kd = jnp.asarray(np.asarray(dist_state.n_kd)[grid.doc_perm])
    z = jnp.asarray(z_corpus)
    zeros = jnp.zeros((E,), jnp.int32)
    st = CGSState(topic=z, prev_topic=z, n_wk=n_wk, n_kd=n_kd,
                  n_k=dist_state.n_k, rng=jax.random.key(0),
                  iteration=jnp.int32(0), stale_iters=zeros,
                  same_count=zeros)
    return float(predictive_llh(st, corpus, hyper))

def eval_sb(st):
    return float(predictive_llh(st, corpus, hyper))

def ppl(llh_val):
    return float(np.exp(-llh_val / E))
"""


@pytest.mark.parametrize("alg", MESH_BACKENDS)
def test_mesh_matches_single_box(alg):
    run_with_devices(COMMON + f"""
from repro.core import LDATrainer, TrainConfig

ITERS = 8
alg = '{alg}'

# --- distributed run on the 2-device mesh ------------------------------
state, data = init_dist_state(jax.random.key(0), mesh, grid, hyper,
                              init_topics=init_grid)
dcfg = resolve_dist_row_pads(state,
                             DistConfig(algorithm=alg, max_kd=0, max_kw=0))
step = make_dist_step(mesh, hyper, dcfg, grid.words_per_shard,
                      grid.docs_per_shard)
l0 = eval_dist(state)
mesh_llhs = [l0]
st = state
for _ in range(ITERS):
    st = step(st, data)
    # count conservation after EVERY sync step
    assert int(jnp.sum(st.n_k)) == E
    np.testing.assert_array_equal(np.asarray(jnp.sum(st.n_wk, 0)),
                                  np.asarray(st.n_k))
    np.testing.assert_array_equal(np.asarray(jnp.sum(st.n_kd, 0)),
                                  np.asarray(st.n_k))
    mesh_llhs.append(eval_dist(st))
l_mesh = mesh_llhs[-1]
assert l_mesh > l0, (l0, l_mesh)
# non-increasing perplexity trend: no point rises >2% above the best so far
best = ppl(mesh_llhs[0])
for v in mesh_llhs[1:]:
    assert ppl(v) <= best * 1.02, (mesh_llhs,)
    best = min(best, ppl(v))

# replay determinism: same jitted step, same init => identical counts
state2, _ = init_dist_state(jax.random.key(0), mesh, grid, hyper,
                            init_topics=init_grid)
st2 = state2
for _ in range(ITERS):
    st2 = step(st2, data)
np.testing.assert_array_equal(np.asarray(st.n_wk), np.asarray(st2.n_wk))
np.testing.assert_array_equal(np.asarray(st.n_k), np.asarray(st2.n_k))

# --- single-box run from the SAME initial assignment -------------------
tr = LDATrainer(corpus, hyper, TrainConfig(algorithm=alg,
                                           sampling_method='gumbel'))
sb = single_box_state(jax.random.key(7))
l0_sb = eval_sb(sb)
np.testing.assert_allclose(l0_sb, l0, rtol=1e-4)  # same init, same metric
sb_llhs = [l0_sb]
for _ in range(ITERS):
    sb = tr.step(sb)
    sb_llhs.append(eval_sb(sb))
sb.check_invariants(corpus)
l_sb = sb_llhs[-1]
assert l_sb > l0_sb, (l0_sb, l_sb)
best = ppl(sb_llhs[0])
for v in sb_llhs[1:]:
    assert ppl(v) <= best * 1.02, (sb_llhs,)
    best = min(best, ppl(v))
# equal iterations from one init land in a common band (trend agreement;
# 15% absorbs mixing-speed differences — e.g. lightlda's mesh proposal is
# locality-restricted and converges a little slower than single-box —
# while still catching a cell that samples garbage, which stalls at init)
assert abs(l_mesh - l_sb) / abs(l_sb) < 0.15, (l_mesh, l_sb)
print('PARITY OK', alg, l0, l_mesh, l_sb)
""", n_devices=2, timeout=900)


@pytest.mark.parametrize("alg", GUMBEL_EXACT)
def test_gumbel_cell_semantics_exact(alg):
    """shard_map step == host-side per-cell emulation, bit-for-bit.

    Reimplements the paper-Fig.-2 workflow on one device — per-cell keys,
    local id translation, cell_sweep on the local blocks, delta merge —
    and checks the distributed step produces EXACTLY the same N_wk / N_kd
    / N_k after the sync. Deterministic for the Gumbel-max backends."""
    run_with_devices(COMMON + f"""
alg = '{alg}'
backend = algorithms.get(alg)
state, data = init_dist_state(jax.random.key(0), mesh, grid, hyper,
                              init_topics=init_grid)
dcfg = DistConfig(algorithm=alg)
step = make_dist_step(mesh, hyper, dcfg, grid.words_per_shard,
                      grid.docs_per_shard)
knobs = backend.resolve_cell_knobs(dcfg.knobs(), hyper)

rows, cols = 1, 2
wps, dps = grid.words_per_shard, grid.docs_per_shard
n_wk0 = np.asarray(state.n_wk); n_kd0 = np.asarray(state.n_kd)
n_k0 = np.asarray(state.n_k)
new_wk = n_wk0.copy(); new_kd = n_kd0.copy(); new_k = n_k0.copy()
base = jax.random.fold_in(state.rng, state.iteration)
for row in range(rows):
    for col in range(cols):
        cell = row * cols + col
        word = jnp.asarray(grid.word[cell]); doc = jnp.asarray(grid.doc[cell])
        mask = jnp.asarray(grid.mask[cell])
        z_old = state.topic[cell]
        word_l = word - col * wps
        doc_l = doc - row * dps
        dev = row * cols + col
        k_sample, _ = jax.random.split(jax.random.fold_in(base, dev))
        n_wk_l = jnp.asarray(n_wk0[col * wps:(col + 1) * wps])
        n_kd_l = jnp.asarray(n_kd0[row * dps:(row + 1) * dps])
        z_prop = backend.cell_sweep(
            k_sample, word_l, doc_l, z_old, mask, n_wk_l, n_kd_l,
            jnp.asarray(n_k0), hyper, grid.num_words_padded, knobs)
        z_new = np.where(np.asarray(mask), np.asarray(z_prop),
                         np.asarray(z_old))
        live = np.asarray(mask)
        w_np = np.asarray(word); d_np = np.asarray(doc)
        zo = np.asarray(z_old)
        for t in np.nonzero(live & (z_new != zo))[0]:
            new_wk[w_np[t], zo[t]] -= 1; new_wk[w_np[t], z_new[t]] += 1
            new_kd[d_np[t], zo[t]] -= 1; new_kd[d_np[t], z_new[t]] += 1
            new_k[zo[t]] -= 1; new_k[z_new[t]] += 1

st = step(state, data)
np.testing.assert_array_equal(np.asarray(st.n_wk), new_wk)
np.testing.assert_array_equal(np.asarray(st.n_kd), new_kd)
np.testing.assert_array_equal(np.asarray(st.n_k), new_k)
print('EXACT OK', alg)
""", n_devices=2, timeout=900)
