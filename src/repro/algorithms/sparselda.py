"""``sparselda`` — SparseLDA (Yao et al.) on the shared substrate (paper
§7.2): s/r/q three-bucket decomposition with linear search, fresh counts.

Mesh-capable: a ``CellBackend`` whose s/r/q rows are sparsified from the
shard-local count blocks, so the same pass runs per mesh cell under
``shard_map`` and over the whole corpus single-box.
"""
from __future__ import annotations

from repro.algorithms.base import CellBackend, SamplerKnobs, kernel_dispatch
from repro.algorithms.registry import register
from repro.core.baselines import sparselda_cell


@register("sparselda")
class SparseLDA(CellBackend):
    """s/r/q bucket sampler; work/token tracks O(K_d + K_w)."""

    needs_row_pads = True

    def cell_sweep(
        self, key, word, doc, z_old, mask, n_wk, n_kd, n_k, hyper,
        num_words_pad, knobs: SamplerKnobs,
    ):
        knobs = self.resolve_cell_knobs(knobs, hyper)
        return sparselda_cell(
            key, word, doc, z_old, n_wk, n_kd, n_k, hyper, num_words_pad,
            knobs.max_kw, knobs.max_kd,
            use_kernel=kernel_dispatch(knobs.kernels),
            bt=knobs.bt, bs=knobs.bs,
        )
